//! The folded nonlinearity: BN + activation + re-quantization in one map.

use crate::act::{qrange, Activation};

/// One output channel's folded activation black box (paper §II-A):
///
/// `F(m) = clamp(round(act(a*m + b) / s_out), qmin, qmax)`
///
/// where `m` is the integer MAC output, `(a, b)` folds the weight/input
/// quantization steps and BatchNorm, and `s_out` is the next layer's
/// activation quantization step.
#[derive(Clone, Debug)]
pub struct FoldedActivation {
    pub a: f64,
    pub b: f64,
    pub act: Activation,
    pub s_out: f64,
    pub n_bits: u8,
}

impl FoldedActivation {
    pub fn new(a: f64, b: f64, act: Activation, s_out: f64, n_bits: u8) -> Self {
        assert!(s_out > 0.0, "output step must be positive");
        FoldedActivation {
            a,
            b,
            act,
            s_out,
            n_bits,
        }
    }

    /// Continuous (pre-quantization) value at MAC output `m`.
    #[inline]
    pub fn real(&self, m: f64) -> f64 {
        self.act.eval(self.a * m + self.b) / self.s_out
    }

    /// The exact quantized output the hardware must reproduce.
    #[inline]
    pub fn eval(&self, m: i64) -> i32 {
        let (qmin, qmax) = qrange(self.n_bits);
        let v = self.real(m as f64).round_ties_even();
        (v as i64).clamp(qmin as i64, qmax as i64) as i32
    }

    /// Sample `n` evenly spaced integer points over `[lo, hi]` (the paper
    /// doubles the observed MAC range and takes 1000 samples).  The
    /// values are clamped to the quantized output rails — the hardware
    /// must reproduce the *clamped* black box (the visible saturation in
    /// the paper's Figure 2 SiLU plots).
    pub fn sample(&self, lo: i64, hi: i64, n: usize) -> Vec<(i64, f64)> {
        assert!(hi > lo && n >= 2);
        let (qmin, qmax) = qrange(self.n_bits);
        let mut pts = Vec::with_capacity(n);
        let span = (hi - lo) as f64;
        let mut last_x = i64::MIN;
        for i in 0..n {
            let x = lo + (span * i as f64 / (n - 1) as f64).round() as i64;
            if x == last_x {
                continue; // dedupe when range < n
            }
            last_x = x;
            let y = self.real(x as f64).clamp(qmin as f64, qmax as f64);
            pts.push((x, y));
        }
        pts
    }

    /// Doubled-range sampling exactly as the paper describes.
    pub fn sample_doubled(&self, mac_lo: i64, mac_hi: i64, n: usize) -> Vec<(i64, f64)> {
        let mid = (mac_lo + mac_hi) / 2;
        let half = ((mac_hi - mac_lo) / 2).max(1);
        self.sample(mid - 2 * half, mid + 2 * half, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_fold_quantizes() {
        let f = FoldedActivation::new(0.01, 0.5, Activation::Relu, 0.05, 8);
        assert_eq!(f.eval(-1000), 0); // act(-9.5) = 0
        assert_eq!(f.eval(0), 10); // 0.5/0.05
        assert_eq!(f.eval(100_000), 127); // clamp
    }

    #[test]
    fn eval_matches_real_rounding() {
        let f = FoldedActivation::new(0.002, -0.3, Activation::Silu, 0.01, 8);
        for m in [-4000i64, -100, 0, 55, 999, 12345] {
            let r = f.real(m as f64).round_ties_even();
            let e = f.eval(m) as f64;
            if (-128.0..=127.0).contains(&r) {
                assert_eq!(e, r, "m={m}");
            }
        }
    }

    #[test]
    fn sampling_covers_doubled_range() {
        let f = FoldedActivation::new(0.001, 0.0, Activation::Sigmoid, 0.004, 8);
        let pts = f.sample_doubled(-1000, 1000, 101);
        assert_eq!(pts.first().unwrap().0, -2000);
        assert_eq!(pts.last().unwrap().0, 2000);
        assert!(pts.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn one_bit_binary_range() {
        let f = FoldedActivation::new(0.01, 0.0, Activation::Identity, 1.0, 1);
        assert_eq!(f.eval(-100_000), -1);
        assert_eq!(f.eval(100_000), 1);
    }
}
