//! The L3 activation service under a multi-tenant workload: many layers
//! (streams) with different activation functions share a small bank of
//! GRAU workers; the service batches per stream and pays explicit
//! reconfiguration cycles on every switch — the paper's runtime
//! reconfigurability as a serving system, driven entirely through the
//! typed `grau::api` facade: every stream is a `StreamHandle` built from
//! a serializable `UnitDescriptor`, and phase 2 *reconfigures* the live
//! handles to refitted descriptors mid-run.
//!
//! ```bash
//! cargo run --release --example reconfig_service -- [requests] [workers]
//! ```

use grau::act::{Activation, FoldedActivation};
use grau::api::{Backend, Pending, ServiceBuilder, StreamHandle, UnitDescriptor};
use grau::fit::pipeline::{fit_folded, FitOptions};
use grau::fit::ApproxKind;
use grau::hw::GrauRegisters;
use grau::util::rng::Rng;
use std::time::Instant;

/// Fit one layer's folded activation and emit its deployable descriptor.
fn fit_layer(i: u64, act: Activation, scale: f64) -> UnitDescriptor {
    let f = FoldedActivation::new(scale, 0.0, act, 1.0 / 120.0, 8);
    let fit = fit_folded(&f, -1500, 1500, FitOptions { n_shifts: 16, ..Default::default() });
    fit.descriptor(ApproxKind::Apot, &format!("layer{i}/{act:?}"))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_req: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(2000);
    let workers: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(4);

    let svc = ServiceBuilder::new()
        .workers(workers)
        .max_batch(16384)
        .backend(Backend::Functional)
        .start();

    // 12 streams = 12 layers with alternating activation functions and
    // scales, all fitted independently (per-layer reconfig state).  Each
    // registration hands back the handle that owns the stream.
    let acts = [Activation::Relu, Activation::Sigmoid, Activation::Silu, Activation::Tanh];
    let mut streams: Vec<(StreamHandle, GrauRegisters)> = Vec::new();
    for i in 0..12u64 {
        let d = fit_layer(i, acts[i as usize % acts.len()], 0.002 + 0.0005 * i as f64);
        let handle = svc.register_descriptor(&d).expect("register stream");
        streams.push((handle, d.regs));
    }

    let mut rng = Rng::new(42);
    let t0 = Instant::now();

    // phase 1: mixed traffic over the fitted bank
    run_wave(&streams, &mut rng, n_req / 2);

    // phase 2: runtime reconfiguration — every layer is refitted at a
    // new scale and the LIVE handles swap their register files via
    // serialized descriptors; traffic then verifies against the NEW fits
    for (i, (handle, regs)) in streams.iter_mut().enumerate() {
        let d = fit_layer(i as u64, acts[i % acts.len()], 0.004 + 0.0003 * i as f64);
        handle.reconfigure(&d).expect("reconfigure stream");
        *regs = d.regs;
    }
    run_wave(&streams, &mut rng, n_req - n_req / 2);

    let dt = t0.elapsed().as_secs_f64();
    let s0 = streams[0].0.metrics();
    println!(
        "  stream 0: {} reqs / {} elements, mean latency {:.0}µs (handle-scoped metrics)",
        s0.completed, s0.elements_out, s0.mean_latency_us()
    );
    drop(streams); // handles evict their streams
    let m = svc.shutdown();
    println!(
        "served {} reqs / {:.1}M elements with {workers} workers in {:.3}s",
        m.requests, m.elements as f64 / 1e6, dt
    );
    println!(
        "  throughput {:.2} Melem/s | batches {} | reconfigs {} ({} cycles) | \
         latency mean {:.0}µs p50 {}µs p99 {}µs max {}µs",
        m.elements as f64 / dt / 1e6, m.batches, m.reconfigs, m.reconfig_cycles,
        m.mean_latency_us(), m.p50_latency_us(), m.p99_latency_us(), m.latency_us_max
    );
    println!(
        "  reconfig amortization: {:.1} elements per reconfig",
        m.elements as f64 / m.reconfigs.max(1) as f64
    );
}

/// Fire `n_req` randomized requests across the stream bank and verify
/// every response bit-exactly against the registered register file.
fn run_wave(streams: &[(StreamHandle, GrauRegisters)], rng: &mut Rng, n_req: usize) {
    let mut pending: Vec<(usize, Vec<i32>, Pending)> = Vec::with_capacity(n_req);
    for _ in 0..n_req {
        let si = rng.range_usize(0, streams.len());
        let n = 1024 + rng.range_usize(0, 3072);
        let data: Vec<i32> = (0..n).map(|_| rng.range_i64(-4000, 4000) as i32).collect();
        let pend = streams[si].0.submit(data.clone()).expect("submit");
        pending.push((si, data, pend));
    }
    for (si, data, pend) in pending {
        let resp = pend.recv().expect("response");
        let regs = &streams[si].1;
        for (x, y) in data.iter().zip(&resp.data) {
            assert_eq!(*y, regs.eval(*x), "stream {si}");
        }
    }
}
