//! Property-based invariants (hand-rolled generator — proptest is not
//! vendored offline).  Each property runs over hundreds of randomized
//! cases with a deterministic seed.

use grau::act::{qrange, Activation, FoldedActivation};
use grau::fit::greedy::{select_breakpoints, GreedyOptions};
use grau::fit::pipeline::{fit_samples, FitOptions};
use grau::fit::slope::quantize_slope;
use grau::fit::ApproxKind;
use grau::hw::{GrauPlan, GrauRegisters, MAX_SEGMENTS, PAD_THRESHOLD};
use grau::api::MetricsSnapshot;
use grau::util::rng::{Rng, Zipf};

fn random_regs(rng: &mut Rng) -> GrauRegisters {
    let n_bits = [1u8, 2, 4, 8][rng.range_usize(0, 4)];
    let segs = rng.range_usize(1, MAX_SEGMENTS + 1);
    let n_shifts = [4u8, 8, 16][rng.range_usize(0, 3)];
    let shift_lo = rng.range_i64(0, 8) as u8;
    let mut r = GrauRegisters::new(n_bits, segs, shift_lo, n_shifts);
    let mut ths: Vec<i32> = (0..segs - 1)
        .map(|_| rng.range_i64(-50_000, 50_000) as i32)
        .collect();
    ths.sort_unstable();
    ths.dedup();
    while ths.len() < segs - 1 {
        ths.push(*ths.last().unwrap_or(&0) + 1 + ths.len() as i32);
    }
    r.thresholds = [PAD_THRESHOLD; MAX_SEGMENTS - 1];
    r.thresholds[..segs - 1].copy_from_slice(&ths[..segs - 1]);
    for j in 0..segs {
        r.x0[j] = rng.range_i64(-50_000, 50_000) as i32;
        let (qmin, qmax) = qrange(n_bits);
        r.y0[j] = rng.range_i64(qmin as i64, qmax as i64 + 1) as i32;
        r.sign[j] = if rng.uniform() < 0.5 { 1 } else { -1 };
        r.mask[j] = (rng.next_u64() as u32) & ((1u32 << n_shifts) - 1);
    }
    r
}

/// Like [`random_regs`] but with a caller-chosen threshold range (narrow
/// ranges exercise the plan's dense segment-index table, wide ranges its
/// linear-search fallback) and the full 4/6/8-bit width set.
fn random_regs_spanned(rng: &mut Rng, th_lo: i64, th_hi: i64) -> GrauRegisters {
    let n_bits = [1u8, 2, 4, 6, 8][rng.range_usize(0, 5)];
    let segs = rng.range_usize(1, MAX_SEGMENTS + 1);
    let n_shifts = [4u8, 8, 16][rng.range_usize(0, 3)];
    let shift_lo = rng.range_i64(0, 8) as u8;
    let mut r = GrauRegisters::new(n_bits, segs, shift_lo, n_shifts);
    let mut ths: Vec<i32> = (0..segs - 1)
        .map(|_| rng.range_i64(th_lo, th_hi) as i32)
        .collect();
    ths.sort_unstable();
    ths.dedup();
    while ths.len() < segs - 1 {
        ths.push(*ths.last().unwrap_or(&0) + 1 + ths.len() as i32);
    }
    ths.sort_unstable();
    r.thresholds = [PAD_THRESHOLD; MAX_SEGMENTS - 1];
    r.thresholds[..segs - 1].copy_from_slice(&ths[..segs - 1]);
    for j in 0..segs {
        r.x0[j] = rng.range_i64(-50_000, 50_000) as i32;
        let (qmin, qmax) = qrange(n_bits);
        r.y0[j] = rng.range_i64(qmin as i64, qmax as i64 + 1) as i32;
        r.sign[j] = if rng.uniform() < 0.5 { 1 } else { -1 };
        r.mask[j] = (rng.next_u64() as u32) & ((1u32 << n_shifts) - 1);
    }
    r
}

/// Re-implementation of the python scalar spec (big-int semantics).
fn spec_eval(r: &GrauRegisters, x: i32) -> i32 {
    let mut seg = 0usize;
    for &t in &r.thresholds[..r.n_segments - 1] {
        if x >= t {
            seg += 1;
        }
    }
    let dx = x as i64 - r.x0[seg] as i64;
    let mut acc = 0i64;
    for k in 0..r.n_shifts as u32 {
        if r.mask[seg] >> k & 1 == 1 {
            acc += dx >> (r.shift_lo as u32 + k);
        }
    }
    let (qmin, qmax) = qrange(r.n_bits);
    (r.y0[seg] as i64 + r.sign[seg] as i64 * acc).clamp(qmin as i64, qmax as i64) as i32
}

#[test]
fn prop_eval_matches_spec_and_stays_in_range() {
    let mut rng = Rng::new(7777);
    for _ in 0..300 {
        let r = random_regs(&mut rng);
        let (qmin, qmax) = qrange(r.n_bits);
        for _ in 0..50 {
            let x = rng.range_i64(i32::MIN as i64 / 2, i32::MAX as i64 / 2) as i32;
            let y = r.eval(x);
            assert_eq!(y, spec_eval(&r, x));
            assert!(y >= qmin && y <= qmax);
        }
    }
}

#[test]
fn prop_plan_matches_registers_bit_for_bit() {
    // GrauPlan::eval / eval_batch must equal GrauRegisters::eval for
    // every input, across all n_shifts windows (4/8/16), 1-8 segments,
    // and 1/2/4/6/8-bit widths — with and without the dense table.
    let mut rng = Rng::new(20_260_727);
    for case in 0..300 {
        // alternate wide threshold spans (linear-search fallback) and
        // narrow spans (dense segment-index table)
        let (lo, hi) = if case % 2 == 0 {
            (-50_000i64, 50_000i64)
        } else {
            (-120i64, 120i64)
        };
        let r = random_regs_spanned(&mut rng, lo, hi);
        let plan = GrauPlan::new(&r);
        let lean = GrauPlan::without_table(&r);
        let mut xs: Vec<i32> = (0..48)
            .map(|_| rng.range_i64(i32::MIN as i64, i32::MAX as i64 + 1) as i32)
            .collect();
        xs.extend((0..48).map(|_| rng.range_i64(lo, hi) as i32));
        // threshold neighbourhoods: the exact boundary and both sides
        for &t in &r.thresholds[..r.n_segments - 1] {
            xs.extend([t.saturating_sub(1), t, t.saturating_add(1)]);
        }
        let batch = plan.eval_vec(&xs);
        for (i, &x) in xs.iter().enumerate() {
            let want = r.eval(x);
            assert_eq!(plan.eval(x), want, "plan x={x} case={case}");
            assert_eq!(lean.eval(x), want, "lean plan x={x} case={case}");
            assert_eq!(batch[i], want, "batch x={x} case={case}");
        }
    }
}

#[test]
fn prop_eval_piecewise_linear_within_segment() {
    // within one segment with sign=+1 and non-zero mask the response is
    // monotone non-decreasing in x (floor-shift sums preserve order)
    let mut rng = Rng::new(99);
    for _ in 0..100 {
        let mut r = random_regs(&mut rng);
        for j in 0..r.n_segments {
            r.sign[j] = 1;
        }
        // pick xs inside one segment (below the first threshold)
        let hi = if r.n_segments > 1 {
            r.thresholds[0].saturating_sub(1)
        } else {
            i32::MAX / 2
        };
        let lo = hi.saturating_sub(10_000);
        let mut xs: Vec<i32> = (0..30).map(|_| rng.range_i64(lo as i64, hi as i64 + 1) as i32).collect();
        xs.sort_unstable();
        let ys: Vec<i32> = xs.iter().map(|&x| r.eval(x)).collect();
        for w in ys.windows(2) {
            assert!(w[1] >= w[0], "monotone within segment");
        }
    }
}

#[test]
fn prop_greedy_breakpoints_sorted_distinct_gapped() {
    let mut rng = Rng::new(31337);
    for _ in 0..50 {
        let n = 200 + rng.range_usize(0, 400);
        let act = [Activation::Sigmoid, Activation::Silu, Activation::Tanh][rng.range_usize(0, 3)];
        let f = FoldedActivation::new(
            0.001 + rng.uniform() * 0.01,
            rng.normal() * 0.3,
            act,
            1.0 / 100.0,
            8,
        );
        let samples = f.sample(-2000, 2000, n);
        let gap = 1 + rng.range_i64(0, 50);
        let opts = GreedyOptions {
            segments: 2 + rng.range_usize(0, 7),
            min_gap: gap,
            eps: 1e-4,
        };
        let bps = select_breakpoints(&samples, opts);
        assert!(bps.len() + 1 <= opts.segments);
        for w in bps.windows(2) {
            assert!(w[1] - w[0] >= gap, "gap violated: {bps:?} gap {gap}");
        }
    }
}

#[test]
fn prop_apot_never_worse_than_pot() {
    let mut rng = Rng::new(4242);
    for _ in 0..500 {
        let slope = rng.normal() * 0.5;
        let shift_lo = rng.range_i64(0, 10) as u8;
        let n_shifts = [4u8, 8, 16][rng.range_usize(0, 3)];
        let p = quantize_slope(slope, shift_lo, n_shifts, ApproxKind::Pot);
        let a = quantize_slope(slope, shift_lo, n_shifts, ApproxKind::Apot);
        assert!(
            (a.value - slope).abs() <= (p.value - slope).abs() + 1e-12,
            "slope {slope} lo {shift_lo} n {n_shifts}: pot {p:?} apot {a:?}"
        );
    }
}

#[test]
fn prop_fit_error_monotone_in_segments() {
    let mut rng = Rng::new(808);
    for _ in 0..20 {
        let act = [Activation::Sigmoid, Activation::Silu][rng.range_usize(0, 2)];
        let f = FoldedActivation::new(0.004, rng.normal() * 0.2, act, 1.0 / 120.0, 8);
        let samples = f.sample(-1500, 1500, 500);
        let e4 = fit_samples(&samples, 8, FitOptions { segments: 4, samples: 500, ..Default::default() });
        let e8 = fit_samples(&samples, 8, FitOptions { segments: 8, samples: 500, ..Default::default() });
        assert!(
            e8.rmse_pwlf <= e4.rmse_pwlf + 1e-9,
            "{act:?}: S=8 rmse {} > S=4 rmse {}",
            e8.rmse_pwlf,
            e4.rmse_pwlf
        );
    }
}

#[test]
fn prop_pareto_front_non_dominated_dropped_dominated_ties_deduped() {
    use grau::hw::dse::{pareto, DsePoint};
    // `q` dominates `p`: no worse on both axes, strictly better on one
    fn dominates(q: &DsePoint, p: &DsePoint) -> bool {
        q.lut <= p.lut && q.rmse <= p.rmse && (q.lut < p.lut || q.rmse < p.rmse)
    }
    let mut rng = Rng::new(20_260_807);
    for case in 0..300 {
        // discrete axis values force plenty of exact ties — the class
        // of input the seed predicate mishandled (kept duplicates and
        // equal-rmse/costlier points)
        let n = rng.range_usize(0, 40);
        let points: Vec<DsePoint> = (0..n)
            .map(|i| DsePoint {
                segments: i,
                exponents: 8,
                rmse: rng.range_i64(0, 6) as f64 * 0.5,
                lut: rng.range_i64(1, 7) as u32 * 100,
                depth: 1,
            })
            .collect();
        let front = pareto(&points);
        assert!(front.len() <= points.len());
        assert_eq!(front.is_empty(), points.is_empty(), "case {case}");

        // 1. the front is mutually non-dominated, with no exact ties
        for (i, p) in front.iter().enumerate() {
            for (j, q) in front.iter().enumerate() {
                if i == j {
                    continue;
                }
                assert!(!dominates(q, p), "case {case}: front point {j} dominates {i}");
                assert!(
                    !(q.lut == p.lut && q.rmse == p.rmse),
                    "case {case}: exact tie survived in the front"
                );
            }
        }
        // 2. every dropped point is dominated by (or exactly ties) a
        //    kept point — nothing non-dominated was lost
        for (i, p) in points.iter().enumerate() {
            let kept = front
                .iter()
                .any(|f| f.lut == p.lut && f.rmse == p.rmse && f.segments == p.segments);
            if !kept {
                assert!(
                    front
                        .iter()
                        .any(|f| dominates(f, p) || (f.lut == p.lut && f.rmse == p.rmse)),
                    "case {case}: dropped point {i} ({p:?}) is not dominated"
                );
            }
        }
        // 3. sorted by LUT ascending, RMSE strictly falling
        for w in front.windows(2) {
            assert!(w[1].lut > w[0].lut, "case {case}: lut order");
            assert!(w[1].rmse < w[0].rmse, "case {case}: rmse not strictly falling");
        }
        // 4. on exact ties the earliest input occurrence wins
        for f in &front {
            let first = points
                .iter()
                .find(|p| p.lut == f.lut && p.rmse == f.rmse)
                .expect("front point originates from the input");
            assert_eq!(first.segments, f.segments, "case {case}: tie-break not first-wins");
        }
    }
}

#[test]
fn prop_zipf_sampler_matches_pmf_chi_square() {
    // Pearson chi-square goodness-of-fit of the sampler against its own
    // pmf: 200k seeded draws over 40 ranks, s = 1.2.  With df = 39 the
    // statistic concentrates around 39 (sd ≈ 8.8); 100 is ~7 sd out, so
    // the deterministic seed passes with enormous margin while any
    // off-by-one in the CDF search or a mis-normalized pmf blows far
    // past it.
    let z = Zipf::new(40, 1.2);
    let mut rng = Rng::new(20_260_807);
    let draws = 200_000usize;
    let mut counts = vec![0u64; z.n()];
    for _ in 0..draws {
        let k = z.sample(&mut rng);
        assert!(k < z.n());
        counts[k] += 1;
    }
    let mut chi2 = 0.0f64;
    for k in 0..z.n() {
        let expect = z.pmf(k) * draws as f64;
        // chi-square validity needs every cell's expected count >= ~5
        assert!(expect > 5.0, "rank {k} expected count {expect}");
        let d = counts[k] as f64 - expect;
        chi2 += d * d / expect;
    }
    assert!(chi2 < 100.0, "chi2 {chi2} rejects the Zipf shape");
    // and the pmf itself is strictly head-heavy
    for k in 1..z.n() {
        assert!(z.pmf(k) < z.pmf(k - 1), "pmf not decreasing at rank {k}");
    }
}

#[test]
fn prop_latency_histogram_quantiles_within_bucket() {
    // the log-scale histogram reports a bucket upper bound; for every
    // adversarial latency set, p50/p99/p999 must land in the same
    // power-of-two bucket as the exact ceil-rank quantile — i.e. never
    // below it and within 2x of it.
    fn bucket(us: u64) -> usize {
        ((64 - us.leading_zeros()) as usize).min(63)
    }
    fn exact_percentile(sorted: &[u64], pct: f64) -> u64 {
        let total = sorted.len() as u64;
        let rank = (((pct / 100.0) * total as f64).ceil() as u64).clamp(1, total);
        sorted[(rank - 1) as usize]
    }
    let mut cases: Vec<Vec<u64>> = vec![
        vec![5; 1000],                   // degenerate: all equal
        vec![1023, 1024, 1025],          // straddles a power-of-two boundary
        vec![0],                         // single zero (bucket 0)
        vec![7],                         // single value
        // heavy tail: 999 fast requests, one catastrophic straggler
        (0..1000).map(|i| if i < 999 { 1 } else { 1 << 40 }).collect(),
        // bimodal: the p50/p99 split sits between the modes
        (0..1000).map(|i| if i % 2 == 0 { 3 } else { 100_000 }).collect(),
    ];
    let mut rng = Rng::new(123_456);
    for _ in 0..50 {
        // log-uniform magnitudes with uniform jitter inside each octave
        let n = 1 + rng.range_usize(0, 5000);
        cases.push(
            (0..n)
                .map(|_| {
                    let base = 1u64 << rng.range_usize(0, 41);
                    base + rng.next_u64() % base.max(1)
                })
                .collect(),
        );
    }
    for (ci, case) in cases.iter().enumerate() {
        let mut snap = MetricsSnapshot::default();
        for &us in case {
            snap.latency_buckets[bucket(us)] += 1;
        }
        let mut sorted = case.clone();
        sorted.sort_unstable();
        for pct in [50.0, 99.0, 99.9] {
            let got = snap.latency_percentile_us(pct);
            let exact = exact_percentile(&sorted, pct);
            assert_eq!(
                bucket(got),
                bucket(exact),
                "case {ci} p{pct}: got {got}, exact {exact}"
            );
            if exact == 0 {
                assert_eq!(got, 0, "case {ci} p{pct}");
            } else {
                assert!(got >= exact, "case {ci} p{pct}: {got} < exact {exact}");
                assert!(got < 2 * exact, "case {ci} p{pct}: {got} >= 2x exact {exact}");
            }
        }
    }
}

#[test]
fn prop_mt_output_monotone_in_input() {
    use grau::hw::mt::MtUnit;
    let mut rng = Rng::new(5150);
    for _ in 0..50 {
        let n_bits = [1u8, 2, 4, 8][rng.range_usize(0, 4)];
        let n_th = (1usize << n_bits) - 1;
        let mut ths: Vec<i32> = (0..n_th).map(|_| rng.range_i64(-9999, 9999) as i32).collect();
        ths.sort_unstable();
        let mt = MtUnit::new(n_bits, ths);
        let mut xs: Vec<i32> = (0..100).map(|_| rng.range_i64(-20_000, 20_000) as i32).collect();
        xs.sort_unstable();
        let ys: Vec<i32> = xs.iter().map(|&x| mt.eval(x)).collect();
        assert!(ys.windows(2).all(|w| w[1] >= w[0]));
    }
}
