"""Kernel-vs-oracle correctness: the CORE L1 signal.

Integer kernels must be *bit-identical* to the pure-jnp oracle AND to the
scalar spec (python big-int arithmetic, no overflow) — three independent
implementations of the same datapath.  Hypothesis sweeps shapes, register
contents and precisions.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.specs import MAX_SEGMENTS, GrauConfig, grau_eval_scalar, mt_eval_scalar, qrange
from compile.kernels import grau_act_cfg, mt_act, quant_matmul
from compile.kernels.ref import grau_act_ref, mt_act_ref, quant_matmul_ref


def make_cfg(rng: np.random.Generator, n_bits: int, n_segments: int,
             shift_lo: int, n_shifts: int, pot_only: bool = False) -> GrauConfig:
    bps = np.sort(rng.choice(np.arange(-4000, 4000), size=n_segments - 1,
                             replace=False)).tolist()
    qmin, qmax = qrange(n_bits)
    x0 = [-5000] + bps
    y0 = rng.integers(qmin, qmax + 1, size=n_segments).tolist()
    sign = rng.choice([-1, 1], size=n_segments).tolist()
    if pot_only:
        mask = [1 << int(rng.integers(0, n_shifts)) if rng.random() > 0.2 else 0
                for _ in range(n_segments)]
    else:
        mask = rng.integers(0, 1 << n_shifts, size=n_segments).tolist()
    return GrauConfig.padded(n_bits, bps, x0, y0, sign, mask, shift_lo, n_shifts)


class TestGrauKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_bits=st.sampled_from([1, 2, 4, 8]),
        n_segments=st.integers(1, MAX_SEGMENTS),
        n_shifts=st.sampled_from([4, 8, 16]),
        shift_lo=st.integers(0, 8),
        pot_only=st.booleans(),
    )
    def test_kernel_matches_ref_and_scalar(self, seed, n_bits, n_segments,
                                           n_shifts, shift_lo, pot_only):
        rng = np.random.default_rng(seed)
        cfg = make_cfg(rng, n_bits, n_segments, shift_lo, n_shifts, pot_only)
        x = rng.integers(-100_000, 100_000, size=1024).astype(np.int32)
        ker = np.asarray(grau_act_cfg(jnp.asarray(x), cfg))
        ref = np.asarray(grau_act_ref(jnp.asarray(x), cfg))
        np.testing.assert_array_equal(ker, ref)
        # scalar spec on a subsample (python ints, no overflow)
        idx = rng.choice(len(x), size=64, replace=False)
        sca = np.array([grau_eval_scalar(cfg, int(x[i])) for i in idx])
        np.testing.assert_array_equal(ker[idx], sca)

    def test_negative_dx_arithmetic_shift(self):
        """dx < 0 must floor-divide (arithmetic shift), not truncate."""
        cfg = GrauConfig.padded(8, [], [0], [0], [1], [0b1], shift_lo=3,
                                n_shifts=4)
        x = jnp.asarray(np.array([-8, -7, -1, 0, 7, 8], np.int32))
        out = np.asarray(grau_act_cfg(jnp.tile(x, 512 // 6 * 6)[:512 * 1], cfg)) \
            if False else np.asarray(grau_act_cfg(jnp.resize(x, (512,)), cfg))
        exp = np.resize(np.array([-1, -1, -1, 0, 0, 1]), 512)
        np.testing.assert_array_equal(out, exp)

    def test_clamp_to_precision(self):
        for n_bits in (2, 4, 8):
            qmin, qmax = qrange(n_bits)
            cfg = GrauConfig.padded(n_bits, [], [0], [0], [1], [0b1],
                                    shift_lo=0, n_shifts=4)
            x = jnp.asarray(np.linspace(-1e6, 1e6, 512).astype(np.int32))
            out = np.asarray(grau_act_cfg(x, cfg))
            assert out.min() == qmin and out.max() == qmax

    def test_zero_mask_is_constant_segment(self):
        cfg = GrauConfig.padded(8, [], [0], [42], [1], [0], 0, 16)
        x = jnp.asarray(np.random.default_rng(0)
                        .integers(-9999, 9999, 512).astype(np.int32))
        np.testing.assert_array_equal(np.asarray(grau_act_cfg(x, cfg)), 42)


class TestMtKernel:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_bits=st.sampled_from([1, 2, 4, 8]))
    def test_matches_ref(self, seed, n_bits):
        rng = np.random.default_rng(seed)
        n_th = (1 << n_bits) - 1
        th = np.sort(rng.choice(np.arange(-30000, 30000), n_th,
                                replace=False)).astype(np.int32)
        x = rng.integers(-50_000, 50_000, 1024).astype(np.int32)
        ker = np.asarray(mt_act(jnp.asarray(x), jnp.asarray(th), n_bits=n_bits))
        ref = np.asarray(mt_act_ref(jnp.asarray(x), jnp.asarray(th), n_bits))
        np.testing.assert_array_equal(ker, ref)
        sca = np.array([mt_eval_scalar(th.tolist(), int(v), n_bits)
                        for v in x[:64]])
        np.testing.assert_array_equal(ker[:64], sca)

    def test_monotone_output(self):
        """MT output is monotone in x — the paper's Figure 1 limitation."""
        th = np.sort(np.random.default_rng(3).choice(
            np.arange(-1000, 1000), 15, replace=False)).astype(np.int32)
        x = np.sort(np.random.default_rng(4)
                    .integers(-2000, 2000, 512)).astype(np.int32)
        out = np.asarray(mt_act(jnp.asarray(x), jnp.asarray(th), n_bits=4))
        assert (np.diff(out) >= 0).all()


class TestQuantMatmul:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        m=st.sampled_from([32, 64]),
        k=st.sampled_from([64, 128, 192]),
        n=st.sampled_from([32, 64]),
        bits=st.sampled_from([2, 4, 8]),
    )
    def test_matches_ref(self, seed, m, k, n, bits):
        rng = np.random.default_rng(seed)
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        a = rng.integers(lo, hi + 1, (m, k)).astype(np.int32)
        b = rng.integers(lo, hi + 1, (k, n)).astype(np.int32)
        ker = np.asarray(quant_matmul(jnp.asarray(a), jnp.asarray(b)))
        ref = np.asarray(quant_matmul_ref(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(ker, ref)
