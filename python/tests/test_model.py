"""L2 model sanity: shapes, training signal, export folding correctness."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M


@pytest.fixture(scope="module")
def mlp():
    spec = M.mlp_spec("sfc_t", [8, 8, 8, 8], in_dim=768)
    params, state = M.init_model(spec, jax.random.PRNGKey(0))
    return spec, params, state


def test_forward_shapes(mlp):
    spec, params, state = mlp
    x = jnp.zeros((4, 768), jnp.float32)
    logits, _ = M.forward(spec, params, state, x, train=True)
    assert logits.shape == (4, 10)


def test_loss_decreases(mlp):
    spec, params, state = mlp
    x_np, y_np = D.teacher_dataset(512, 768, 10)
    x, y = jnp.asarray(x_np), jnp.asarray(y_np)
    step = jax.jit(M.make_train_step(spec, 2e-3))
    opt = M.adam_init(params)
    losses = []
    for i in range(30):
        b = slice((i * 64) % 512, (i * 64) % 512 + 64)
        params, state, opt, loss = step(params, state, opt, x[b], y[b])
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8


def test_mixed_precision_memory_ordering():
    """Quantized weight bytes: full1 < mixed < full8 (Table I's premise)."""

    def nbytes(bits):
        spec = M.mlp_spec("m", bits, in_dim=768)
        total = 0
        for op in spec.ops:
            if op.kind == "linear":
                total += op.out_ch * (768 if op.name == "fc0" else
                                      256 if op.name != "head" else 256) \
                    * op.w_bits / 8
        return total

    assert nbytes([1, 1, 1, 1]) < nbytes([1, 2, 4, 8]) < nbytes([8] * 4)


def test_export_fold_matches_forward(mlp):
    """The folded integer path must reproduce the fake-quant forward.

    Quantize input -> integer MAC (layer 0) -> folded float z = a*mac + b
    must equal BN(conv(x_q, w_q)) from the float fake-quant forward.
    """
    spec, params, state = mlp
    # give state non-trivial statistics
    x_np, y_np = D.teacher_dataset(256, 768, 10)
    step = jax.jit(M.make_train_step(spec, 2e-3))
    opt = M.adam_init(params)
    for i in range(10):
        params, state, opt, _ = step(params, state, opt,
                                     jnp.asarray(x_np[:64]),
                                     jnp.asarray(y_np[:64]))
    exp = M.export_layers(spec, params, state)
    in_step = float(exp["in_step"])
    w_int = np.asarray(exp["fc0/w_int"]).astype(np.int64)
    a = np.asarray(exp["fc0/a"], np.float64)
    b = np.asarray(exp["fc0/b"], np.float64)

    x = x_np[:8]
    x_q = np.clip(np.rint(x / in_step), -128, 127).astype(np.int64)
    mac = x_q @ w_int
    z_folded = a * mac + b

    # reference: float fake-quant forward up to fc0's BN output
    w = params["fc0/w"]
    wq = np.asarray(M.fake_quant(w, M.weight_step(w, 8), 8), np.float64)
    z = (x_q * in_step) @ wq
    mu = np.asarray(state["fc0/mu"], np.float64)
    var = np.asarray(state["fc0/var"], np.float64)
    gamma = np.asarray(params["fc0/gamma"], np.float64)
    beta = np.asarray(params["fc0/beta"], np.float64)
    z_ref = gamma * (z - mu) / np.sqrt(var + M.BN_EPS) + beta

    np.testing.assert_allclose(z_folded, z_ref, rtol=1e-4, atol=1e-5)


def test_resnet_residual_graph_wiring():
    spec = M.resnet18s_spec("rn_t", [8, 8, 8, 8, 8], silu_stage4=True,
                            n_classes=100)
    adds = [op for op in spec.ops if op.kind == "add"]
    assert len(adds) == 8  # 4 stages x 2 blocks
    for op in adds:
        assert 0 <= op.rhs < len(spec.ops) and 0 <= op.lhs < len(spec.ops)
        assert spec.ops[op.lhs].kind == "conv"
    # stage-4 blocks use silu
    assert all(op.act == "silu" for op in adds[-2:])
    assert all(op.act == "relu" for op in adds[:6])
    p, s = M.init_model(spec, jax.random.PRNGKey(0))
    logits, _ = M.forward(spec, p, s, jnp.zeros((2, 32, 32, 3)), train=True)
    assert logits.shape == (2, 100)


def test_one_bit_weights_are_binary():
    spec = M.mlp_spec("b", [1, 1, 1, 1], in_dim=768)
    p, s = M.init_model(spec, jax.random.PRNGKey(0))
    exp = M.export_layers(spec, p, s)
    w = np.asarray(exp["fc0/w_int"])
    assert set(np.unique(w)) <= {-1.0, 1.0}


def test_vgg_stage_bits_assignment():
    spec = M.vgg16s_spec("v", [8, 4, 2, 4, 8], "silu")
    convs = [op for op in spec.ops if op.kind == "conv"]
    assert [op.w_bits for op in convs] == [8, 8, 4, 4, 2, 2, 2, 4, 4, 4, 8, 8, 8]
