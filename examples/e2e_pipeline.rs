//! END-TO-END DRIVER (DESIGN.md §6): the full system on a real small
//! workload, proving all layers compose.
//!
//!   1. PJRT runtime loads the AOT-lowered QAT train step (L2 JAX, built
//!      once by `make artifacts`) and trains the 8-bit CNV QNN on the
//!      CIFAR-like dataset, logging the loss curve.
//!   2. The trained model is folded (`export`) into the integer engine.
//!   3. Per-channel MAC ranges are calibrated; every activation site is
//!      fitted (greedy Algorithm 1 -> PoT/APoT register files).
//!   4. Accuracy is measured under Exact / PWLF / PoT / APoT activation
//!      paths (the paper's Tables III/IV protocol).
//!   5. The fitted register files are exported as a serialized
//!      `UnitDescriptor` bank, loaded back from disk, and replayed
//!      through the cycle-accurate pipelined GRAU via the typed service
//!      facade — checked bit-for-bit against the functional model (the
//!      fit → file → service round trip).
//!   6. Headline metrics: accuracy deltas, LUT reduction vs MT, service
//!      throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::path::Path;

use grau::api::{Backend, DescriptorBank, ServiceBuilder, UnitDescriptor};
use grau::coordinator::fitting::{eval_mode, fit_model_with_ranges, SweepOptions};
use grau::coordinator::trainer::{dataset_for, train_config};
use grau::fit::ApproxKind;
use grau::hw::cost::{estimate, UnitKind};
use grau::hw::unit::UnitKind as BackendKind;
use grau::qnn::{ActMode, Engine};
use grau::runtime::Runtime;

fn main() -> grau::error::Result<()> {
    let artifacts = Path::new("artifacts");
    // the 8-bit CNV — the mixed-precision variant is demonstrated by
    // examples/mixed_precision_accelerator.rs; the 8-bit model trains to
    // the paper's accuracy regime and makes the approximation deltas
    // meaningful
    let config = "t1_cnn_full8";
    let steps: usize = std::env::var("GRAU_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(350);

    // ---- 1+2: train through the runtime, export the integer bundle ----
    println!("== [1/6] training {config} for {steps} steps through PJRT ==");
    let rt = Runtime::cpu()?;
    let tr = train_config(&rt, artifacts, config, steps, true, true)?;
    if !tr.from_cache {
        let show = |i: usize| tr.losses.get(i).copied().unwrap_or(f32::NAN);
        println!(
            "loss curve: step0 {:.3} -> mid {:.3} -> final {:.3} (float top1 {:.3})",
            show(0), show(tr.losses.len() / 2),
            tr.losses.last().copied().unwrap_or(f32::NAN), tr.float_top1
        );
    } else {
        println!("(loaded from weight cache)");
    }

    // ---- 3: calibrate + fit every activation site ----------------------
    println!("== [3/6] calibrating MAC ranges + fitting all sites ==");
    let splits = dataset_for(config);
    let exact = Engine::new(tr.graph.clone(), &tr.bundle, ActMode::Exact)?;
    let opts = SweepOptions { segments: 6, n_shifts: 8, ..Default::default() };
    let ranges = exact.calibrate(&splits.train, opts.calib_samples);
    let fits = fit_model_with_ranges(&exact, &ranges, opts);
    let n_units: usize = exact.site_channels().iter().sum();
    println!("fitted {n_units} per-channel GRAU units across {} sites; apot window {}",
             exact.site_channels().len(), fits.apot_window);

    // ---- 4: accuracy under each activation path -------------------------
    println!("== [4/6] accuracy: Exact vs PWLF vs PoT vs APoT ==");
    let orig = exact.evaluate(&splits.test, opts.eval_samples, opts.threads);
    println!("  original (exact folded)  top1 {:.4}", orig.top1);
    let mut apot_top1 = 0.0;
    for kind in [ApproxKind::Pwlf, ApproxKind::Pot, ApproxKind::Apot] {
        let r = eval_mode(&tr.graph, &tr.bundle, fits.act_mode(kind), &splits.test, opts);
        println!("  {:<24} top1 {:.4}  (delta {:+.4})", kind.name(), r.top1, r.top1 - orig.top1);
        if kind == ApproxKind::Apot {
            apot_top1 = r.top1;
        }
    }

    // ---- 5: fit -> file -> cycle-accurate replay through the service ----
    println!("== [5/6] descriptor export + cycle-accurate replay through the service ==");
    // export the first site's channels as a serialized descriptor bank,
    // pinned to the cycle-accurate pipelined backend...
    let mut bank = DescriptorBank::new(config);
    for (ch, regs) in fits.apot[0].iter().enumerate().take(8) {
        bank.insert(
            format!("site0/ch{ch}"),
            UnitDescriptor::new(regs.clone(), ApproxKind::Apot).with_unit(BackendKind::Pipelined),
        );
    }
    let bank_path = std::env::temp_dir().join("grau_e2e.units.json");
    bank.save(&bank_path)?;
    // ...and load it back from disk to drive the service, as a deployed
    // accelerator would
    let bank = DescriptorBank::load(&bank_path)?;
    println!("  exported + reloaded {} descriptors via {bank_path:?}", bank.len());
    let svc = ServiceBuilder::new().workers(2).backend(Backend::CycleSim).start();
    let mut checked = 0usize;
    for (ch, (name, d)) in bank.iter().enumerate() {
        let stream = svc.register_descriptor(d)?;
        let (lo, hi) = ranges.ranges[0][ch];
        let xs: Vec<i32> = (0..512).map(|i| lo + ((hi - lo).max(1) / 512 * i)).collect();
        let resp = stream.call(xs.clone())?;
        let regs = &fits.apot[0][ch];
        for (x, y) in xs.iter().zip(&resp.data) {
            assert_eq!(*y, regs.eval(*x), "{name}: hardware != functional at x={x}");
        }
        checked += xs.len();
    }
    let m = svc.shutdown();
    println!(
        "  verified {checked} elements bit-exact; sim cycles {} reconfig cycles {}",
        m.sim_cycles, m.reconfig_cycles
    );

    // ---- 6: headline ----------------------------------------------------
    println!("== [6/6] headline ==");
    let g = estimate(UnitKind::GrauPipelined { kind: ApproxKind::Apot, segments: 6, exponents: 8 });
    let mt = estimate(UnitKind::MtPipelined { n_bits: 8 });
    println!(
        "  accuracy: original {:.2}% -> APoT-PWLF {:.2}% ({:+.2} pts)",
        100.0 * orig.top1, 100.0 * apot_top1, 100.0 * (apot_top1 - orig.top1)
    );
    println!(
        "  hardware: {} vs {} LUTs -> {:.1}% reduction; Fmax {} vs {} MHz",
        g.lut, mt.lut, 100.0 * (1.0 - g.lut as f64 / mt.lut as f64),
        g.fmax_mhz, mt.fmax_mhz
    );
    println!("e2e pipeline OK");
    Ok(())
}
